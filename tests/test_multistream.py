"""Batched multi-stream serving (repro.core.multistream).

The batched driver must be a pure batching transform: each stream's result
through ``louvain_dynamic_batched`` equals what that stream would get alone,
and the batched pass loop handles per-stream convergence (tolerance
freezing) and capacity discipline (fleet-level growth + replay by default,
typed FleetCapacityOverflow when growth is off).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.delta import make_edge_batch
from repro.core.dynamic import louvain_dynamic
from repro.core.graph import build_csr
from repro.core.louvain import (LouvainConfig, louvain,
                                membership_modularity, pad_membership)
from repro.core.multistream import (FleetCapacityOverflow, louvain_batched,
                                    louvain_dynamic_batched, stack_batches,
                                    stack_graphs)
from repro.data import sbm_graph, sbm_holdout_stream


def _stream_case(seed, n_cap=128, e_cap=1400, n_hold=32, n_steps=4,
                 b_cap=8):
    init, batches, _ = sbm_holdout_stream(
        seed, n_cap=n_cap, e_cap=e_cap, n_hold=n_hold, n_steps=n_steps,
        b_cap=b_cap)
    return init, batches


@pytest.fixture(scope="module")
def fleet():
    cases = [_stream_case(seed) for seed in (10, 11, 12, 13)]
    return [c[0] for c in cases], [c[1] for c in cases]


def test_stack_graphs_rejects_mixed_capacities():
    g1, _ = _stream_case(0, e_cap=1400)
    g2, _ = _stream_case(1, e_cap=1500)
    with pytest.raises(ValueError, match="capacities differ"):
        stack_graphs([g1, g2])


def test_stack_batches_rejects_mixed_capacities():
    _, b1 = _stream_case(0, b_cap=8)
    _, b2 = _stream_case(1, b_cap=16)
    with pytest.raises(ValueError, match="capacities differ"):
        stack_batches([b1[0], b2[0]])


def test_batched_cold_matches_per_stream_louvain(fleet):
    """Cold batched pass loop == per-stream louvain(), membership for
    membership (identical engine, identical rounds — the vmap must be
    semantics-preserving)."""
    graphs, _ = fleet
    res = louvain_batched(stack_graphs(graphs))
    for s, g in enumerate(graphs):
        solo = louvain(g)
        n = int(g.n_valid)
        assert np.array_equal(np.asarray(res.membership[s, :n]),
                              solo.membership), s
        assert int(res.n_communities[s]) == solo.n_communities


def test_batched_dynamic_matches_sequential_dynamic(fleet):
    """louvain_dynamic_batched == S independent louvain_dynamic runs."""
    graphs, streams = fleet
    res = louvain_dynamic_batched(graphs, streams, track_modularity=True)
    for s in range(len(graphs)):
        solo = louvain_dynamic(graphs[s], streams[s])
        assert np.array_equal(res.stream_membership(s), solo.membership), s
        q = membership_modularity(solo.graph, solo.membership)
        assert abs(float(res.modularity[s]) - q) < 1e-5


def test_batched_dynamic_vertex_screening(fleet):
    """Per-vertex affected flags flow through the batched path too and
    produce strictly smaller seed frontiers."""
    graphs, streams = fleet
    res_c = louvain_dynamic_batched(graphs, streams, screening="community",
                                    track_modularity=True)
    res_v = louvain_dynamic_batched(graphs, streams, screening="vertex",
                                    track_modularity=True)
    assert np.all(res_v.frontier_sizes <= res_c.frontier_sizes)
    assert np.all(res_v.frontier_sizes.sum(0) <
                  res_c.frontier_sizes.sum(0))
    # quality stays at the community-screened level on these corpora
    assert np.all(res_v.modularity > res_c.modularity - 0.02)


def test_batched_fallback_path_matches_sequential(fleet):
    """A deliberately bad warm start (all singletons) makes step 0's move
    run >1 sweep, forcing the optimistic pipeline to redo the stream
    through the per-step validated loop + general pass loop — results must
    still equal the sequential driver exactly."""
    graphs, streams = fleet
    prevs = [np.arange(int(g.n_valid), dtype=np.int32) for g in graphs]
    res = louvain_dynamic_batched(graphs, streams, prevs=prevs)
    for s in range(len(graphs)):
        solo = louvain_dynamic(graphs[s], streams[s], prev=prevs[s])
        assert np.array_equal(res.stream_membership(s), solo.membership), s


def test_batched_zero_step_streams(fleet):
    """An idle fleet (no pending updates) returns the warm memberships
    unchanged, like louvain_dynamic(graph, [])."""
    graphs, _ = fleet
    prevs = [louvain(g).membership for g in graphs]
    res = louvain_dynamic_batched(graphs, [[] for _ in graphs], prevs=prevs)
    assert res.frontier_sizes.shape[0] == 0
    for s, p in enumerate(prevs):
        assert np.array_equal(res.stream_membership(s), p)


def test_batched_accepts_sentinel_padded_prevs(fleet):
    """prevs in the (n_cap + 1,) sentinel layout (pad_membership output)
    are accepted, same contract as louvain_dynamic."""
    graphs, streams = fleet
    flat = [louvain(g).membership for g in graphs]
    padded = [pad_membership(p, graphs[0].n_cap) for p in flat]
    res_flat = louvain_dynamic_batched(graphs, streams, prevs=flat)
    res_pad = louvain_dynamic_batched(graphs, streams, prevs=padded)
    assert np.array_equal(res_flat.membership, res_pad.membership)


def test_batched_dynamic_pallas_apply_matches(fleet):
    graphs, streams = fleet
    res_x = louvain_dynamic_batched(graphs, streams)
    res_p = louvain_dynamic_batched(graphs, streams, apply_backend="pallas")
    assert np.array_equal(res_x.membership, res_p.membership)


def _tight_whale_fleet():
    """A 2-stream fleet with almost no edge headroom plus a batch of
    brand-new edges that cannot fit the provisioned envelope."""
    full, _ = sbm_graph(n_communities=4, size=8, p_in=0.5, p_out=0.05,
                        seed=1)
    e = int(full.e_valid)
    g = build_csr(np.asarray(full.src)[:e], np.asarray(full.indices)[:e],
                  np.asarray(full.weights)[:e], int(full.n_valid),
                  e_cap=e + 2)   # almost no headroom
    batch = make_edge_batch([0, 1, 2, 3], [17, 18, 19, 20],
                            [1.0, 1.0, 1.0, 1.0], g.n_cap, b_cap=4)
    return g, batch


def test_batched_overflow_is_loud_without_growth():
    g, batch = _tight_whale_fleet()
    with pytest.raises(FleetCapacityOverflow, match="overflows capacity"):
        louvain_dynamic_batched([g, g], [[batch], [batch]],
                                prevs=[louvain(g).membership] * 2,
                                grow_capacity=False)


def test_batched_overflow_regrows_and_matches():
    """A whale stream overflowing the envelope re-buckets the FLEET and
    replays the step — the serving run completes and equals the same fleet
    provisioned with ample headroom up front (memberships are invariant to
    capacity)."""
    g, batch = _tight_whale_fleet()
    prevs = [louvain(g).membership] * 2
    grown = louvain_dynamic_batched([g, g], [[batch], [batch]], prevs=prevs)
    assert grown.n_regrows >= 1

    e = int(g.e_valid)
    ample = build_csr(np.asarray(g.src)[:e], np.asarray(g.indices)[:e],
                      np.asarray(g.weights)[:e], int(g.n_valid),
                      e_cap=int(grown.graphs.indices.shape[1]))
    ref = louvain_dynamic_batched([ample, ample], [[batch], [batch]],
                                  prevs=prevs)
    assert ref.n_regrows == 0
    assert np.array_equal(grown.membership, ref.membership)


def test_batched_rejects_ell_config(fleet):
    graphs, _ = fleet
    with pytest.raises(ValueError, match="sort-reduce"):
        louvain_batched(stack_graphs(graphs),
                        LouvainConfig(use_ell_kernel=True))


def test_batched_ladder_membership_padding_is_sentinel():
    """Laddered fleet passes must NOT leak a shrunk tier's sentinel into
    invalid membership slots — a later warm start would misread a small
    stale value as a real community assignment (regression test for the
    fleet-ladder sanitization)."""
    from repro.core.graph import rebucket_graph

    g1, _ = sbm_graph(16, 48, p_in=0.25, p_out=0.004, seed=2)
    g2, _ = sbm_graph(12, 64, p_in=0.30, p_out=0.003, seed=3)
    n_cap = max(g1.n_cap, g2.n_cap)
    e_cap = max(g1.e_cap, g2.e_cap)
    gb = stack_graphs([rebucket_graph(g1, n_cap, e_cap),
                       rebucket_graph(g2, n_cap, e_cap)])
    for ladder in (True, False):
        res = louvain_batched(gb, LouvainConfig(use_ladder=ladder))
        mem = np.asarray(res.membership)
        for s, g in enumerate((g1, g2)):
            n = int(g.n_valid)
            assert np.all(mem[s, n:] == n_cap), (ladder, s)


def test_batched_auto_screening_resolves_host_side(fleet):
    """screening="auto" under vmap must NOT silently evaluate both
    granularities on device: the driver resolves the mode host-side per
    step from the previous step's worst touched fraction, records the
    concrete choice (plus the first-step downgrade) in pass_stats, and the
    result equals chaining the recorded modes explicitly."""
    graphs, streams = fleet
    prevs = [louvain(g).membership for g in graphs]
    res = louvain_dynamic_batched(graphs, streams, prevs=prevs,
                                  screening="auto")
    modes = [s.screening for s in res.pass_stats]
    assert len(modes) == len(streams[0])
    assert all(m in ("community", "vertex") for m in modes)  # concrete
    # First dispatch has no measurement: safe community mode, flagged.
    assert modes[0] == "community"
    assert res.pass_stats[0].downgraded
    # Replaying the stream with the RECORDED mode per step must reproduce
    # the auto run bit-for-bit (auto is routing, never results).
    cur = list(graphs)
    mems = list(prevs)
    for t, mode in enumerate(modes):
        step = louvain_dynamic_batched(
            cur, [s[t:t + 1] for s in streams], prevs=mems, screening=mode)
        mems = [step.membership[s] for s in range(len(cur))]
        cur = [jax.tree.map(lambda x, s=s: x[s], step.graphs)
               for s in range(len(cur))]
    assert np.array_equal(res.membership, np.stack(mems))


def test_batched_scan_auto_downgrade_is_explicit(fleet):
    """scan_backend="auto" cannot be honored per-batch under vmap; the
    driver must record the downgrade to the full scan instead of silently
    keeping it, and results must equal the explicit full scan."""
    graphs, streams = fleet
    res_auto = louvain_dynamic_batched(
        graphs, streams, config=LouvainConfig(scan_backend="auto"),
        screening="community")
    assert all(s.scan_backend == "full" for s in res_auto.pass_stats)
    assert all(s.downgraded for s in res_auto.pass_stats)
    res_full = louvain_dynamic_batched(
        graphs, streams, config=LouvainConfig(scan_backend="full"),
        screening="community")
    assert not any(s.downgraded for s in res_full.pass_stats)
    assert np.array_equal(res_auto.membership, res_full.membership)


def _live_edge_multiset(gb, s, n_cap):
    src = np.asarray(gb.src[s]); dst = np.asarray(gb.indices[s])
    w = np.asarray(gb.weights[s])
    live = src < n_cap
    rows = np.stack([src[live], dst[live], w[live].astype(np.float64)])
    return rows[:, np.lexsort(rows[::-1])]


def test_midstream_overflow_replay_matches_oneshot_bitforbit():
    """A batch overflowing MID-stream (earlier steps already committed,
    step 0 even forced through the general pass loop by a bad warm start)
    regrows the fleet and replays from the PRE-apply state: the partially
    applied overflow batch must never be applied twice.  Pinned by
    bit-for-bit equality of memberships AND live edge content against the
    same stream served with ample capacity up front."""
    full, _ = sbm_graph(n_communities=4, size=8, p_in=0.5, p_out=0.05,
                        seed=5)
    e = int(full.e_valid)
    n = int(full.n_valid)
    g = build_csr(np.asarray(full.src)[:e], np.asarray(full.indices)[:e],
                  np.asarray(full.weights)[:e], n, e_cap=e + 6)

    def batch(k, seed):
        r = np.random.default_rng(seed)
        s = r.integers(0, n, k)
        d = (s + 1 + r.integers(0, n - 1, k)) % n
        return make_edge_batch(s, d, np.ones(k, np.float32), g.n_cap,
                               b_cap=8)

    streams = [[batch(2, 1), batch(8, 2), batch(2, 3)],
               [batch(2, 4), batch(8, 5), batch(2, 6)]]
    prevs = [np.arange(n, dtype=np.int32)] * 2   # singletons: step 0 redoes
    grown = louvain_dynamic_batched([g, g], streams, prevs=prevs)
    assert grown.n_regrows >= 1

    ample = build_csr(np.asarray(g.src)[:e], np.asarray(g.indices)[:e],
                      np.asarray(g.weights)[:e], n,
                      e_cap=int(grown.graphs.indices.shape[1]))
    ref = louvain_dynamic_batched([ample, ample], streams, prevs=prevs)
    assert ref.n_regrows == 0
    assert np.array_equal(grown.membership, ref.membership)
    for s in range(2):
        assert np.array_equal(_live_edge_multiset(grown.graphs, s, g.n_cap),
                              _live_edge_multiset(ref.graphs, s, g.n_cap)), s
