"""Pallas batch-apply group-resolve kernel vs the XLA sort-reduce reference.

The kernel (``repro.kernels.batch_apply``) replaces the post-sort resolve of
``repro.core.delta.sort_reduce_apply_slots`` with one carry-chained scan; it
must be BIT-identical to the XLA path — weights are selected, never summed,
so equality is exact, not approximate.  Runs in interpret mode on CPU (the
CI kernel step); the same code path compiles on TPU.
"""

import numpy as np
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # optional dev dep — see tests/_hypothesis_fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.delta import (_apply_edge_batch, make_edge_batch,
                              sort_reduce_apply_slots)
from repro.core.distributed import ShardedGraphSpec
from repro.core.distributed_dynamic import apply_batch_shard
from repro.core.graph import build_csr


def _random_graph(rng, n=32, e_und=80, e_slack=64, self_loops=True):
    us = rng.integers(0, n, e_und)
    ud = rng.integers(0, n, e_und)
    if not self_loops:
        ud = np.where(us == ud, (ud + 1) % n, ud)
    w = rng.uniform(0.25, 4.0, e_und).astype(np.float32)
    off = us != ud
    src = np.concatenate([us, ud[off]])
    dst = np.concatenate([ud, us[off]])
    ww = np.concatenate([w, w[off]])
    return build_csr(src, dst, ww, n, e_cap=len(src) + e_slack)


def _random_batch(rng, n_cap, bs, b_cap):
    bsrc = rng.integers(0, n_cap, bs)
    bdst = rng.integers(0, n_cap, bs)
    bw = np.where(rng.random(bs) < 0.3, 0.0,
                  rng.uniform(0.25, 4.0, bs)).astype(np.float32)
    return make_edge_batch(bsrc, bdst, bw, n_cap, b_cap=b_cap)


def _assert_graphs_equal(g1, g2):
    for name, a, b in zip(g1._fields, g1, g2):
        assert np.array_equal(np.asarray(a), np.asarray(b)), name


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 31))
def test_apply_backends_bit_identical(seed):
    """graph', touched, e_new agree exactly across a random stream."""
    rng = np.random.default_rng(seed)
    g = _random_graph(rng)
    for _ in range(3):
        batch = _random_batch(rng, g.n_cap, int(rng.integers(1, 12)), 16)
        g_x, t_x, e_x = _apply_edge_batch(g, batch, backend="xla")
        g_p, t_p, e_p = _apply_edge_batch(g, batch, backend="pallas")
        _assert_graphs_equal(g_x, g_p)
        assert np.array_equal(np.asarray(t_x), np.asarray(t_p))
        assert int(e_x) == int(e_p)
        g = g_x


def test_apply_backends_agree_on_deletes_and_reweights():
    rng = np.random.default_rng(7)
    g = _random_graph(rng, n=16, e_und=30, self_loops=True)
    e = int(g.e_valid)
    src = np.asarray(g.src)[:e]
    dst = np.asarray(g.indices)[:e]
    # delete 3 existing edges, reweight 3, insert 2, one self loop
    bsrc = np.concatenate([src[:3], src[3:6], [1, 2], [5]])
    bdst = np.concatenate([dst[:3], dst[3:6], [9, 10], [5]])
    bw = np.concatenate([np.zeros(3), [9.0, 8.0, 7.0],
                         [1.5, 2.5], [3.0]]).astype(np.float32)
    batch = make_edge_batch(bsrc, bdst, bw, g.n_cap, b_cap=12)
    g_x, t_x, e_x = _apply_edge_batch(g, batch, backend="xla")
    g_p, t_p, e_p = _apply_edge_batch(g, batch, backend="pallas")
    _assert_graphs_equal(g_x, g_p)
    assert np.array_equal(np.asarray(t_x), np.asarray(t_p))
    assert int(e_x) == int(e_p)


def test_sharded_apply_backends_bit_identical():
    """Per-shard apply (no collectives) agrees across backends shard-by-shard."""
    rng = np.random.default_rng(3)
    spec = ShardedGraphSpec(n_shards=4, v_per_shard=8, e_per_shard=48,
                            n_pad=32)
    sent = spec.sentinel
    # per-shard slot arrays owned by shard 1
    shard_ix = jnp.int32(1)
    e_src = rng.integers(8, 16, 30).astype(np.int32)       # owned by shard 1
    e_dst = rng.integers(0, 32, 30).astype(np.int32)
    e_w = rng.uniform(0.5, 2.0, 30).astype(np.float32)
    pad = np.full(spec.e_per_shard - 30, sent, np.int32)
    src_l = jnp.asarray(np.concatenate([e_src, pad]))
    dst_l = jnp.asarray(np.concatenate([e_dst, pad]))
    w_l = jnp.asarray(np.concatenate([e_w, np.zeros(len(pad), np.float32)]))
    b_src = jnp.asarray(rng.integers(0, 32, 8).astype(np.int32))
    b_dst = jnp.asarray(rng.integers(0, 32, 8).astype(np.int32))
    b_w = jnp.asarray(np.where(rng.random(8) < 0.4, 0.0,
                               rng.uniform(0.5, 2.0, 8)).astype(np.float32))
    outs = {}
    for backend in ("xla", "pallas"):
        outs[backend] = apply_batch_shard(
            spec, shard_ix, src_l, dst_l, w_l, b_src, b_dst, b_w,
            jnp.int32(8), None, backend)
    for a, b in zip(outs["xla"], outs["pallas"]):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_resolve_handles_full_capacity_no_dead_slots():
    """The kernel's trailing pad slot finalizes the last group even when
    every input slot is live (no sentinel slot inside the array)."""
    sent = 8
    # 4 live groups, last group runs to the very end of the slot list
    s_src = jnp.asarray([0, 0, 1, 2, 2, 3], jnp.int32)
    s_dst = jnp.asarray([1, 1, 0, 2, 2, 3], jnp.int32)
    s_w = jnp.asarray([1.0, 2.0, 1.0, 0.5, 3.0, 4.0], jnp.float32)
    rank = jnp.asarray([0, 1, 0, 0, 1, 1], jnp.int32)
    is_batch = jnp.asarray([False, True, False, False, True, True])
    out = {}
    for backend in ("xla", "pallas"):
        out[backend] = sort_reduce_apply_slots(
            s_src, s_dst, s_w, rank, is_batch, sent, 6, backend)
    # graph outputs + e_new identical
    for a, b in zip(out["xla"][:4], out["pallas"][:4]):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert int(out["xla"][3]) == 4
    # changed endpoints scatter to the same touched set
    def touched(chg_src, chg_dst):
        t = np.zeros(sent + 1, bool)
        t[np.asarray(chg_src)] = True
        t[np.asarray(chg_dst)] = True
        t[sent] = False
        return t
    assert np.array_equal(touched(*out["xla"][4:]),
                          touched(*out["pallas"][4:]))


def test_kernel_multi_tile_carry():
    """Slot lists longer than one kernel tile exercise the SMEM carry chain
    (group spanning a tile boundary included)."""
    rng = np.random.default_rng(11)
    n = 700                     # > _BLOCK=512 -> at least two tiles
    g = _random_graph(rng, n=64, e_und=n, e_slack=128)
    batch = _random_batch(rng, g.n_cap, 40, 64)
    g_x, t_x, e_x = _apply_edge_batch(g, batch, backend="xla")
    g_p, t_p, e_p = _apply_edge_batch(g, batch, backend="pallas")
    _assert_graphs_equal(g_x, g_p)
    assert np.array_equal(np.asarray(t_x), np.asarray(t_p))
    assert int(e_x) == int(e_p)
