"""Dynamic streaming Louvain: edge-batch CSR updates (invariants, property)
and warm-start + delta-screening quality vs cold static recompute."""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # optional dev dep — see tests/_hypothesis_fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.delta import apply_edge_batch, make_edge_batch
from repro.core.dynamic import delta_frontier, louvain_dynamic
from repro.core.graph import build_csr
from repro.core.louvain import (louvain, louvain_modularity,
                                membership_modularity as _q)
from repro.data import sbm_graph


def _ref_graph(g):
    """Host adjacency dict {(u,v): w} over directed live slots."""
    e = int(g.e_valid)
    src = np.asarray(g.src)[:e]
    dst = np.asarray(g.indices)[:e]
    w = np.asarray(g.weights)[:e]
    return {(int(s), int(d)): float(x) for s, d, x in zip(src, dst, w)}


def _ref_apply(adj, us, vs, ws):
    """Reference semantics: set weight on both directed slots; 0 deletes."""
    for u, v, w in zip(us, vs, ws):
        for key in {(int(u), int(v)), (int(v), int(u))}:
            if w > 0:
                adj[key] = float(w)
            else:
                adj.pop(key, None)
    return adj


def _assert_csr_well_formed(g):
    n_cap, e_cap = g.n_cap, g.e_cap
    e = int(g.e_valid)
    src = np.asarray(g.src)
    dst = np.asarray(g.indices)
    w = np.asarray(g.weights)
    indptr = np.asarray(g.indptr)
    # live prefix / sentinel padding split
    assert np.all(src[:e] < n_cap) and np.all(dst[:e] < n_cap)
    assert np.all(src[e:] == n_cap) and np.all(dst[e:] == n_cap)
    assert np.all(w[e:] == 0)
    # indptr matches the slot list and slots are in CSR order
    assert indptr[0] == 0 and indptr[-1] == e
    counts = np.bincount(src[:e], minlength=n_cap)
    np.testing.assert_array_equal(np.diff(indptr), counts)
    order = src[:e].astype(np.int64) * (n_cap + 1) + dst[:e]
    assert np.all(np.diff(order) > 0), "slots not in strict (src, dst) order"


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_apply_edge_batch_invariants_random(seed):
    """K_i / 2m invariants + exact adjacency vs a host reference under
    random insert/delete/reweight sequences (property)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(6, 16))
    e0 = int(rng.integers(2, 3 * n))
    src = rng.integers(0, n, e0)
    dst = rng.integers(0, n, e0)
    w = (rng.random(e0) + 0.1).astype(np.float32)
    # Fixed capacities across examples: every draw reuses ONE compiled
    # _apply_edge_batch (the whole point of the in-capacity design).
    g = build_csr(src, dst, w, n, symmetrize=True, dedup=True,
                  n_cap=16, e_cap=192)
    adj = _ref_graph(g)

    for _ in range(4):
        b = int(rng.integers(1, 8))
        us = rng.integers(0, n, b)
        vs = rng.integers(0, n, b)
        # mix of deletes (0), inserts and reweights; last-write-wins in batch
        ws = np.where(rng.random(b) < 0.3, 0.0,
                      (rng.random(b) * 2 + 0.1)).astype(np.float32)
        # drop in-batch duplicates of the same undirected edge (semantics is
        # last-write-wins; the reference dict applies in order, keep both)
        g, touched = apply_edge_batch(
            g, make_edge_batch(us, vs, ws, g.n_cap, b_cap=8))
        adj = _ref_apply(adj, us, vs, ws)

        _assert_csr_well_formed(g)
        assert _ref_graph(g) == pytest.approx(adj)
        # K_i == row sums of the reference; sum(K) == 2m
        k = np.asarray(g.vertex_weights())
        k_ref = np.zeros(n)
        for (s, _), x in adj.items():
            k_ref[s] += x
        np.testing.assert_allclose(k[:n], k_ref, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(k.sum(), 2 * float(g.total_weight()),
                                   rtol=1e-5)
        # touched ⊆ endpoints of the batch
        t_ix = set(np.where(np.asarray(touched))[0].tolist())
        assert t_ix <= set(us.tolist()) | set(vs.tolist())


def test_apply_edge_batch_insert_delete_reweight():
    src = np.array([0, 1, 1, 2, 3, 4])
    dst = np.array([1, 0, 2, 1, 4, 3])
    g = build_csr(src, dst, np.ones(6, np.float32), 5, e_cap=16)
    batch = make_edge_batch([2, 0, 1], [3, 1, 2], [1.0, 0.0, 5.0],
                            g.n_cap, b_cap=4)
    g2, touched = apply_edge_batch(g, batch)
    assert _ref_graph(g2) == {(1, 2): 5.0, (2, 1): 5.0, (2, 3): 1.0,
                              (3, 2): 1.0, (3, 4): 1.0, (4, 3): 1.0}
    assert float(g2.total_weight()) == 7.0
    np.testing.assert_array_equal(
        np.where(np.asarray(touched))[0], [0, 1, 2, 3])
    _assert_csr_well_formed(g2)
    # no-op batch (reweight to the same value, delete of absent edge)
    g3, touched3 = apply_edge_batch(
        g2, make_edge_batch([1, 0], [2, 4], [5.0, 0.0], g2.n_cap))
    assert not bool(jnp.any(touched3))
    assert _ref_graph(g3) == _ref_graph(g2)


def test_apply_edge_batch_self_loop_single_slot():
    g = build_csr(np.array([0, 1]), np.array([1, 0]),
                  np.ones(2, np.float32), 3, e_cap=8)
    g2, _ = apply_edge_batch(g, make_edge_batch([2], [2], [3.0], g.n_cap))
    assert _ref_graph(g2) == {(0, 1): 1.0, (1, 0): 1.0, (2, 2): 3.0}
    assert float(g2.total_weight()) == pytest.approx(2.5)  # m = sum(w)/2


def test_apply_edge_batch_overflow_raises():
    g = build_csr(np.array([0, 1]), np.array([1, 0]),
                  np.ones(2, np.float32), 4, e_cap=4)
    big = make_edge_batch([0, 1, 2], [2, 3, 3], [1.0, 1.0, 1.0], g.n_cap)
    with pytest.raises(ValueError, match="overflow"):
        apply_edge_batch(g, big)


def test_apply_edge_batch_grow_rebuckets():
    """grow=True: an overflowing batch re-buckets into doubled capacity and
    produces the same adjacency a big-enough buffer would have."""
    g = build_csr(np.array([0, 1]), np.array([1, 0]),
                  np.ones(2, np.float32), 4, e_cap=4)
    big = make_edge_batch([0, 1, 2], [2, 3, 3], [1.0, 1.0, 1.0], g.n_cap)
    g2, touched = apply_edge_batch(g, big, grow=True)
    assert g2.e_cap >= 8  # doubled
    ref = build_csr(np.array([0, 1]), np.array([1, 0]),
                    np.ones(2, np.float32), 4, e_cap=16)
    ref2, touched_ref = apply_edge_batch(ref, big)
    assert _ref_graph(g2) == pytest.approx(_ref_graph(ref2))
    np.testing.assert_array_equal(np.asarray(touched), np.asarray(touched_ref))
    _assert_csr_well_formed(g2)


def test_dynamic_stream_grows_capacity():
    """A stream engineered to overflow e_cap completes via re-bucketing
    (grow_capacity default) with the same result as an ample buffer, and
    raises with grow_capacity=False."""
    full, _ = sbm_graph(n_communities=8, size=16, p_in=0.4, p_out=0.01,
                        seed=5)
    e = int(full.e_valid)
    src = np.asarray(full.src)[:e]
    dst = np.asarray(full.indices)[:e]
    und = src < dst
    us, ud = src[und], dst[und]
    rng = np.random.default_rng(1)
    hold = rng.choice(len(us), 40, replace=False)
    keep = np.ones(len(us), bool)
    keep[hold] = False
    n = int(full.n_valid)

    def make_init(e_cap):
        return build_csr(np.concatenate([us[keep], ud[keep]]),
                         np.concatenate([ud[keep], us[keep]]),
                         np.ones(2 * int(keep.sum()), np.float32), n,
                         e_cap=e_cap)

    tight = make_init(2 * int(keep.sum()) + 8)   # room for ~4 more edges
    ample = make_init(e + 8)
    batches = [make_edge_batch(us[hold[i::8]], ud[hold[i::8]],
                               np.ones(len(us[hold[i::8]]), np.float32),
                               n, b_cap=8) for i in range(8)]
    prev = louvain(ample).membership  # same initial graph, any capacity

    dyn_t = louvain_dynamic(tight, batches, prev=prev)
    dyn_a = louvain_dynamic(ample, batches, prev=prev)
    assert dyn_t.graph.e_cap > tight.e_cap          # grew
    assert int(dyn_t.graph.e_valid) == e            # stream fully applied
    q_t = _q(dyn_t.graph, dyn_t.membership)
    q_a = _q(dyn_a.graph, dyn_a.membership)
    assert abs(q_t - q_a) < 0.02, (q_t, q_a)

    with pytest.raises(ValueError, match="overflow"):
        louvain_dynamic(tight, batches, prev=prev, grow_capacity=False)


def test_dynamic_stats_n_touched_matches_eager_recount():
    """Regression (timing-free): n_touched is collected lazily after the
    stream; it must equal an eager per-batch recount of the same stream."""
    full, _ = sbm_graph(n_communities=8, size=16, p_in=0.4, p_out=0.01,
                        seed=9)
    e = int(full.e_valid)
    src = np.asarray(full.src)[:e]
    dst = np.asarray(full.indices)[:e]
    und = src < dst
    us, ud = src[und], dst[und]
    rng = np.random.default_rng(2)
    hold = rng.choice(len(us), 24, replace=False)
    keep = np.ones(len(us), bool)
    keep[hold] = False
    init = build_csr(np.concatenate([us[keep], ud[keep]]),
                     np.concatenate([ud[keep], us[keep]]),
                     np.ones(2 * int(keep.sum()), np.float32),
                     int(full.n_valid), e_cap=e + 8)
    batches = [make_edge_batch(us[hold[i::4]], ud[hold[i::4]],
                               np.ones(len(us[hold[i::4]]), np.float32),
                               init.n_cap, b_cap=8) for i in range(4)]
    prev = louvain(init).membership

    dyn = louvain_dynamic(init, batches, prev=prev)
    g = init
    expected = []
    for b in batches:
        g, touched = apply_edge_batch(g, b)
        expected.append(int(jnp.sum(touched)))
    assert [s.n_touched for s in dyn.batch_stats] == expected
    assert all(s.n_touched >= 0 for s in dyn.batch_stats)


def test_delta_frontier_screens_to_affected_communities():
    # comm: {0,1} -> 0, {2,3} -> 2, {4,5} -> 4 ; touching vertex 0 pulls in
    # community 0's members but nobody else.
    membership = jnp.asarray([0, 0, 2, 2, 4, 4, 6], jnp.int32)
    touched = jnp.asarray([True, False, False, False, False, False, False])
    fr = np.asarray(delta_frontier(touched, membership, jnp.int32(6)))
    np.testing.assert_array_equal(fr, [True, True, False, False, False,
                                       False, False])


def test_warm_start_on_unchanged_graph_is_stable():
    """Re-running from the converged membership must keep quality and stop
    after a single pass (the dq <= tolerance fast path)."""
    g, _ = sbm_graph(n_communities=8, size=16, p_in=0.4, p_out=0.01, seed=2)
    cold = louvain(g)
    warm = louvain(g, init_membership=cold.membership)
    assert warm.n_passes == 1
    assert louvain_modularity(g, warm) >= louvain_modularity(g, cold) - 1e-6


def test_dynamic_stream_matches_static_recompute():
    """Acceptance: SBM streamed as 20 edge batches — dynamic modularity
    within 1% of a cold static recompute on the final graph, while the
    delta-screened frontier re-processes < 25% of vertices per batch."""
    n_comms, size = 64, 16
    full, truth = sbm_graph(n_communities=n_comms, size=size, p_in=0.4,
                            p_out=0.002, seed=11)
    n = int(full.n_valid)
    e = int(full.e_valid)
    src = np.asarray(full.src)[:e]
    dst = np.asarray(full.indices)[:e]
    w = np.asarray(full.weights)[:e]
    und = src < dst
    us, ud, uw = src[und], dst[und], w[und]

    # Hold out 100 intra-community edges; stream them back as 20 batches.
    rng = np.random.default_rng(0)
    intra = np.where(truth[us] == truth[ud])[0]
    hold = rng.choice(intra, 100, replace=False)
    keep = np.ones(len(us), bool)
    keep[hold] = False
    init = build_csr(np.concatenate([us[keep], ud[keep]]),
                     np.concatenate([ud[keep], us[keep]]),
                     np.concatenate([uw[keep], uw[keep]]), n,
                     e_cap=e + 8)
    batches = [make_edge_batch(us[hold[i::20]], ud[hold[i::20]],
                               uw[hold[i::20]], init.n_cap, b_cap=8)
               for i in range(20)]

    prev = louvain(init)
    dyn = louvain_dynamic(init, batches, prev=prev.membership)
    assert len(dyn.batch_stats) == 20

    static = louvain(dyn.graph)
    q_dyn = _q(dyn.graph, dyn.membership)
    q_static = louvain_modularity(dyn.graph, static)
    assert q_dyn >= q_static - 0.01 * abs(q_static), (q_dyn, q_static)

    # Delta screening kept every per-batch seed frontier small.
    fracs = [s.frontier_fraction for s in dyn.batch_stats]
    assert max(fracs) < 0.25, fracs
    # ... and the final graph really is the full SBM again.
    assert int(dyn.graph.e_valid) == e


def test_dynamic_without_screening_matches_with():
    """Pure naive-dynamic (frontier = all vertices) reaches the same quality
    — screening is an optimization, not a semantics change."""
    full, _ = sbm_graph(n_communities=8, size=16, p_in=0.4, p_out=0.01,
                        seed=5)
    e = int(full.e_valid)
    src = np.asarray(full.src)[:e]
    dst = np.asarray(full.indices)[:e]
    und = src < dst
    us, ud = src[und], dst[und]
    rng = np.random.default_rng(1)
    hold = rng.choice(len(us), 20, replace=False)
    keep = np.ones(len(us), bool)
    keep[hold] = False
    init = build_csr(np.concatenate([us[keep], ud[keep]]),
                     np.concatenate([ud[keep], us[keep]]),
                     np.ones(2 * int(keep.sum()), np.float32),
                     int(full.n_valid), e_cap=e + 8)
    batches = [make_edge_batch(us[hold[i::4]], ud[hold[i::4]],
                               np.ones(len(us[hold[i::4]]), np.float32),
                               init.n_cap, b_cap=8) for i in range(4)]
    prev = louvain(init).membership
    dyn_nd = louvain_dynamic(init, batches, prev=prev, screening=False)
    dyn_ds = louvain_dynamic(init, batches, prev=prev, screening=True)
    q_nd = _q(dyn_nd.graph, dyn_nd.membership)
    q_ds = _q(dyn_ds.graph, dyn_ds.membership)
    assert abs(q_nd - q_ds) < 0.02, (q_nd, q_ds)
    # ND re-processes everything; DS must not.
    assert all(s.frontier_size == s.n_vertices for s in dyn_nd.batch_stats)
    assert all(s.frontier_size < s.n_vertices for s in dyn_ds.batch_stats)
