"""The dry-run Louvain phases: the all_to_all aggregation variant must
produce the same coarse graph as the gather baseline (subprocess, 8 devices),
and the arch protocol must lower on a local mesh."""

import json
import os
import subprocess
import sys

import pytest

from conftest import multi_device as _multi_device

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import functools, json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.louvain_arch import (_aggregate_a2a_body,
                                        _aggregate_gather_body)
from repro.core.distributed import ShardedGraphSpec

P_SHARDS = 8
rng = np.random.default_rng(0)
n, e_l = 64, 48                     # per-shard edges
e = P_SHARDS * e_l
spec = ShardedGraphSpec(P_SHARDS, n // P_SHARDS, e_l, n)

src = rng.integers(0, n, e).astype(np.int32)
dst = rng.integers(0, n, e).astype(np.int32)
w = rng.random(e).astype(np.float32) + 0.1
# 12 community ids spread evenly over the vertex-id range, so each shard
# owns <= 2 communities and coarse-edge ownership stays within e_l
# (the skewed/overflow case is tested separately below).
ids = (np.arange(12) * n) // 12
comm_map = ids[rng.integers(0, 12, n)].astype(np.int32)
comm = jnp.asarray(np.concatenate([comm_map, [n]]))  # sentinel slot

from repro.compat import make_mesh
mesh = make_mesh((P_SHARDS,), ("i",))
axes = ("i",)
edge, rep = P("i"), P()

def run(body):
    fn = shard_map(body, mesh=mesh, in_specs=(edge, edge, edge, rep),
                   out_specs=(edge, edge, edge, rep, rep), check_rep=False)
    with mesh:
        return jax.jit(fn)(jnp.asarray(src), jnp.asarray(dst),
                           jnp.asarray(w), comm)

def coarse_dict(ci, cj, cw):
    ci, cj, cw = np.asarray(ci), np.asarray(cj), np.asarray(cw)
    out = {}
    for a, b, x in zip(ci, cj, cw):
        if a < n:
            out[(int(a), int(b))] = out.get((int(a), int(b)), 0.0) + float(x)
    return out

base = run(functools.partial(_aggregate_gather_body, axes, spec))
a2a = run(functools.partial(_aggregate_a2a_body, axes, spec, 8))

# ground truth from numpy
truth = {}
for s_, d_, ww in zip(comm_map[src], comm_map[dst], w):
    truth[(int(s_), int(d_))] = truth.get((int(s_), int(d_)), 0.0) + float(ww)

d_base, d_a2a = coarse_dict(*base[:3]), coarse_dict(*a2a[:3])
keys_match = set(d_base) == set(d_a2a) == set(truth)
max_diff = max((abs(d_base[k] - d_a2a[k]) for k in d_base), default=0.0)
max_vs_truth = max(abs(d_a2a[k] - truth[k]) for k in truth)

# skewed case: 8 communities all owned by shard 0 (ids < v_per) -> up to 64
# coarse pairs on one shard, beyond e_l=48 -> overflow must be flagged
comm_skew = jnp.asarray(np.concatenate(
    [rng.integers(0, 8, n).astype(np.int32), [n]]))
def run_skew(body):
    fn = shard_map(body, mesh=mesh, in_specs=(edge, edge, edge, rep),
                   out_specs=(edge, edge, edge, rep, rep), check_rep=False)
    with mesh:
        return jax.jit(fn)(jnp.asarray(src), jnp.asarray(dst),
                           jnp.asarray(w), comm_skew)
skew = run_skew(functools.partial(_aggregate_gather_body, axes, spec))

# --- delta-encoded move round == baseline round (singleton start) -----------
from repro.configs.louvain_arch import _move_round_delta
from repro.core.distributed import _round_body

k_arr = np.zeros(n + 1, np.float32)
np.add.at(k_arr, src, w)
k_j = jnp.asarray(k_arr)
m_tot = jnp.float32(w.sum() / 2)
comm0 = jnp.asarray(np.concatenate([np.arange(n), [n]]).astype(np.int32))
sigma0 = k_j
sizes0 = jnp.asarray(np.concatenate([np.ones(n), [0]]).astype(np.int32))

def base_round(src_l, dst_l, w_l, comm_, sigma_, k_, m_):
    frontier = jnp.ones((spec.v_per_shard,), bool)
    return _round_body(axes, spec, src_l, dst_l, w_l, comm_, sigma_, k_,
                       frontier, jnp.int32(0), 2, m_)

fn_b = shard_map(base_round, mesh=mesh,
                 in_specs=(edge, edge, edge, rep, rep, rep, rep),
                 out_specs=(rep, rep, edge, rep), check_rep=False)
fn_d = shard_map(functools.partial(_move_round_delta, axes, spec, 1),
                 mesh=mesh,
                 in_specs=(edge, edge, edge, rep, rep, rep, rep, rep),
                 out_specs=(rep, rep, rep, edge, rep, rep), check_rep=False)
with mesh:
    cb, sb, fb, dqb = jax.jit(fn_b)(jnp.asarray(src), jnp.asarray(dst),
                                    jnp.asarray(w), comm0, sigma0, k_j,
                                    m_tot)
    cd, sd, szd, fd, dqd, ovf = jax.jit(fn_d)(
        jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w), comm0, sigma0,
        sizes0, k_j, m_tot)

move_match = bool(jnp.all(cb == cd))
sigma_diff = float(jnp.max(jnp.abs(sb - sd)))
dq_diff = abs(float(dqb) - float(dqd))
n_moved = int(jnp.sum(cd[:-1] != comm0[:-1]))

print(json.dumps({
    "keys_match": keys_match, "max_diff": max_diff,
    "max_vs_truth": max_vs_truth,
    "e_valid_base": int(base[3]), "e_valid_a2a": int(a2a[3]),
    "base_owned_max": int(base[4]), "a2a_dropped": int(a2a[4]),
    "skew_owned_max": int(skew[4]), "e_l": e_l,
    "n_coarse_edges": len(d_base),
    "move_match": move_match, "sigma_diff": sigma_diff,
    "dq_diff": dq_diff, "n_moved": n_moved,
    "move_overflow": int(ovf)}))
"""


@pytest.fixture(scope="module")
def agg_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@_multi_device
def test_a2a_aggregation_matches_gather_baseline(agg_results):
    r = agg_results
    assert r["keys_match"], r
    assert r["max_diff"] < 1e-4, r
    assert r["max_vs_truth"] < 1e-4, r
    assert r["e_valid_base"] == r["e_valid_a2a"]
    assert r["a2a_dropped"] == 0
    assert r["base_owned_max"] <= r["e_l"]
    assert r["n_coarse_edges"] > 10


@pytest.mark.slow
@_multi_device
def test_gather_baseline_overflow_detected(agg_results):
    """Community-ownership skew beyond per-shard capacity must be flagged
    (the silent-drop bug this test originally caught)."""
    r = agg_results
    assert r["skew_owned_max"] > r["e_l"], r


@pytest.mark.slow
@_multi_device
def test_delta_encoded_move_round_matches_baseline(agg_results):
    """The delta-C exchange reconstructs exactly the same (C, Σ, dQ) as the
    dense all_gather/psum round."""
    r = agg_results
    assert r["move_overflow"] <= 0, r
    assert r["move_match"], r
    assert r["sigma_diff"] < 1e-4, r
    assert r["dq_diff"] < 1e-4, r
    assert r["n_moved"] > 0, "test vacuous — no vertex moved"


def test_louvain_arch_lowers_locally():
    import jax
    from repro.compat import make_mesh
    from repro.configs.louvain_arch import ARCH
    mesh = make_mesh((1, 1), ("data", "model"))
    for shape in ("road_108M_move", "road_108M_aggregate"):
        fn, args, shardings = ARCH.build_step(shape, mesh, smoke=True)
        with mesh:
            jax.jit(fn, in_shardings=shardings).lower(*args).compile()
