"""The vocab-sharded distributed-softmax CE must equal the dense loss
(value AND gradient) — verified on 8 forced host devices in a subprocess."""

import json
import os
import subprocess
import sys

import pytest

from conftest import multi_device as _multi_device

pytestmark = [pytest.mark.slow, _multi_device]

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.lm_common import make_sharded_ce
from repro.configs.qwen2_1p5b import ARCH
from repro.models import transformer as tf

cfg = ARCH.smoke_config()
params = tf.init_params(cfg, jax.random.PRNGKey(0))
from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))

b, s = 4, 16
toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
batch = {"tokens": toks, "labels": toks}

with mesh:
    dense = float(jax.jit(lambda p: tf.loss_fn(cfg, p, batch))(params))
    sharded_loss = make_sharded_ce(cfg, mesh)
    sharded = float(jax.jit(lambda p: sharded_loss(p, batch))(params))

    g_dense = jax.jit(jax.grad(lambda p: tf.loss_fn(cfg, p, batch)))(params)
    g_shard = jax.jit(jax.grad(lambda p: sharded_loss(p, batch)))(params)

diffs = jax.tree.map(
    lambda a, b_: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                        - b_.astype(jnp.float32)))),
    g_dense, g_shard)
max_diff = max(jax.tree.leaves(diffs))
scale = max(float(jnp.max(jnp.abs(x.astype(jnp.float32))))
            for x in jax.tree.leaves(g_dense))
print(json.dumps({"dense": dense, "sharded": sharded,
                  "grad_max_diff": max_diff, "grad_scale": scale}))
"""


@pytest.fixture(scope="module")
def ce_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_sharded_ce_value(ce_results):
    r = ce_results
    assert abs(r["dense"] - r["sharded"]) < 2e-3 * max(abs(r["dense"]), 1), r


def test_sharded_ce_grads(ce_results):
    r = ce_results
    assert r["grad_max_diff"] < 5e-3 * max(r["grad_scale"], 1e-6), r
